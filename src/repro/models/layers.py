"""Composable transformer layers with logical-axis sharding annotations.

Everything here is pure-JAX (dry-run lowerable on any backend); the Pallas
kernels in repro.kernels are drop-in replacements on TPU (cfg.use_pallas).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..sharding import logical

f32 = jnp.float32


# ---------------------------------------------------------------- param utils
def mk_param(key, shape, axes, dtype, scale: Optional[float] = None,
             fan_in_dims: Tuple[int, ...] = (0,)):
    """Truncated-normal fan-in init; returns the array (axes tracked by caller)."""
    if scale is None:
        fan_in = int(np.prod([shape[d] for d in fan_in_dims]))
        scale = fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2, 2, shape, f32) * scale
            ).astype(dtype)


def keygen(key):
    def gen():
        nonlocal key
        key, sub = jax.random.split(key)
        return sub
    return gen


# --------------------------------------------------------------------- norms
def rms_norm(x, scale, eps=1e-6):
    x32 = x.astype(f32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def layer_norm(x, scale, bias, eps=1e-5):
    x32 = x.astype(f32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * scale + bias


def apply_norm(cfg, p, x, prefix=''):
    if cfg.norm == 'layernorm':
        return layer_norm(x, p[prefix + 'scale'], p[prefix + 'bias'])
    return rms_norm(x, p[prefix + 'scale'])


def init_norm(cfg, dtype):
    params = {'scale': jnp.ones((cfg.d_model,), dtype)}
    axes = {'scale': ('embed',)}
    if cfg.norm == 'layernorm':
        params['bias'] = jnp.zeros((cfg.d_model,), dtype)
        axes['bias'] = ('embed',)
    return params, axes


# ---------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=f32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, n_heads, head_dim); positions: (S,) or (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    ang = positions.astype(f32)[..., None] * freqs     # (..., S, d/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------- chunked flash attention
NEG_INF = -1e30


def _chunk_mask(q_pos, k_pos, causal, window, kv_len):
    """q_pos: (Sq,), k_pos: (ck,) absolute positions -> (Sq, ck) bool.

    ``window`` may be a *traced* f32 scalar (jnp.inf = no window) so hybrid
    models can switch layers between SWA and global attention inside a scan.
    """
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), jnp.bool_)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m &= (q_pos[:, None] - k_pos[None, :]).astype(f32) < window
    if kv_len is not None:
        m &= k_pos[None, :] < kv_len
    return m


_QG_AXES = ('batch', 'kv_heads', None, 'seq_q', 'head_dim_act')
_KV_AXES = ('batch', 'kv_heads', None, 'head_dim_act')


def _attn_fwd_scan(q, k, v, scale, causal, window, chunk, offset, kv_len):
    """Online-softmax forward.  q: (B,Hk,G,Sq,D); k,v: (B,Hk,Sk,D).
    Returns (o, lse) with o: (B,Hk,G,Sq,D), lse: (B,Hk,G,Sq) fp32.

    Shardings are pinned here (not only at the projection outputs) so XLA
    cannot re-shard the score contraction dim mid-loop — that would turn
    every score block into an all-reduce (measured: 1.7 TB/chip/step on
    qwen3 train before this constraint)."""
    b, hk, g, sq, d = q.shape
    sk = k.shape[2]
    n_chunks = sk // chunk
    q = logical(q, *_QG_AXES)
    k = logical(k, *_KV_AXES)
    v = logical(v, *_KV_AXES)
    q32 = q.astype(f32)
    q_pos = jnp.arange(sq) + offset

    kc = k.reshape(b, hk, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hk, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)

    def step(carry, inp):
        m_prev, l_prev, acc = carry
        c_idx, k_c, v_c = inp
        k_pos = c_idx * chunk + jnp.arange(chunk)
        s = jnp.einsum('bhgqd,bhkd->bhgqk', q32, k_c.astype(f32),
                       preferred_element_type=f32) * scale
        mask = _chunk_mask(q_pos, k_pos, causal, window, kv_len)
        s = jnp.where(mask, s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        m_safe = jnp.where(m_new == NEG_INF, 0.0, m_new)
        # masked s is NEG_INF, so exp() already zeroes those entries — no
        # second score-sized select (saves one full score-block HBM pass)
        p = jnp.exp(s - m_safe[..., None])
        alpha = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_safe))
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            'bhgqk,bhkd->bhgqd', p, v_c.astype(f32), preferred_element_type=f32)
        acc = logical(acc, *_QG_AXES)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hk, g, sq), NEG_INF, f32)
    l0 = jnp.zeros((b, hk, g, sq), f32)
    acc0 = jnp.zeros((b, hk, g, sq, d), f32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0),
                                  (jnp.arange(n_chunks), kc, vc))
    l_safe = jnp.where(l == 0.0, 1.0, l)
    o = acc / l_safe[..., None]
    lse = jnp.where(l == 0.0, NEG_INF, m + jnp.log(l_safe))
    return o, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _chunked_attention(q, k, v, window, scale, causal, chunk, offset):
    o, _ = _attn_fwd_scan(q, k, v, scale, causal, window, chunk, offset, None)
    return o


def _ca_fwd(q, k, v, window, scale, causal, chunk, offset):
    o, lse = _attn_fwd_scan(q, k, v, scale, causal, window, chunk, offset, None)
    return o, (q, k, v, o, lse, window)


def _ca_bwd(scale, causal, chunk, offset, res, do):
    """FlashAttention-2 style backward: recompute p per chunk from (q,k,lse)."""
    q, k, v, o, lse, window = res
    b, hk, g, sq, d = q.shape
    sk = k.shape[2]
    n_chunks = sk // chunk
    q = logical(q, *_QG_AXES)
    k = logical(k, *_KV_AXES)
    v = logical(v, *_KV_AXES)
    do = logical(do, *_QG_AXES)
    q32, do32, o32 = q.astype(f32), do.astype(f32), o.astype(f32)
    q_pos = jnp.arange(sq) + offset
    delta = jnp.sum(do32 * o32, axis=-1)                       # (B,Hk,G,Sq)
    lse_safe = jnp.where(lse == NEG_INF, 0.0, lse)

    kc = k.reshape(b, hk, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hk, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)

    def step(dq_acc, inp):
        c_idx, k_c, v_c = inp
        k_pos = c_idx * chunk + jnp.arange(chunk)
        s = jnp.einsum('bhgqd,bhkd->bhgqk', q32, k_c.astype(f32),
                       preferred_element_type=f32) * scale
        mask = _chunk_mask(q_pos, k_pos, causal, window, None)
        p = jnp.where(mask, jnp.exp(s - lse_safe[..., None]), 0.0)
        dv_c = jnp.einsum('bhgqk,bhgqd->bhkd', p, do32,
                          preferred_element_type=f32)
        dp = jnp.einsum('bhgqd,bhkd->bhgqk', do32, v_c.astype(f32),
                        preferred_element_type=f32)
        ds = p * (dp - delta[..., None]) * scale
        dq_acc = dq_acc + jnp.einsum('bhgqk,bhkd->bhgqd', ds, k_c.astype(f32),
                                     preferred_element_type=f32)
        dq_acc = logical(dq_acc, *_QG_AXES)
        dk_c = jnp.einsum('bhgqk,bhgqd->bhkd', ds, q32,
                          preferred_element_type=f32)
        return dq_acc, (dk_c, dv_c)

    dq0 = jnp.zeros_like(q32)
    dq, (dk_c, dv_c) = jax.lax.scan(step, dq0,
                                    (jnp.arange(n_chunks), kc, vc))
    dk = dk_c.transpose(1, 2, 0, 3, 4).reshape(b, hk, sk, d)
    dv = dv_c.transpose(1, 2, 0, 3, 4).reshape(b, hk, sk, d)
    dwin = None if window is None else jnp.zeros_like(window)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype), dwin


_chunked_attention.defvjp(_ca_fwd, _ca_bwd)


def chunked_attention(q, k, v, *, causal=True, window=None, chunk=512,
                      offset=None, kv_len=None, sm_scale=None):
    """Memory-bounded attention.  q: (B,H,Sq,D); k,v: (B,Hkv,Sk,D).

    kv_len (traced) selects the inference path (no vjp); otherwise the
    FA2-style custom-vjp path is used (residuals O(S), not O(S^2)).
    """
    b, h, sq, d = q.shape
    hk, sk = k.shape[1], k.shape[2]
    group = h // hk
    scale = (d ** -0.5) if sm_scale is None else sm_scale
    if offset is None:
        offset = sk - sq if causal else 0
    chunk = min(chunk, sk)
    if sk % chunk != 0:                # pad keys; masked out via kv_len
        pad = (-sk) % chunk
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kv_len = sk if kv_len is None else kv_len
    if isinstance(window, int):
        window = jnp.float32(window)
    qg = q.reshape(b, hk, group, sq, d)
    if kv_len is None:
        o = _chunked_attention(qg, k, v, window, scale, causal, chunk, offset)
    else:
        o, _ = _attn_fwd_scan(qg, k, v, scale, causal, window, chunk, offset,
                              kv_len)
    return o.reshape(b, h, sq, d).astype(q.dtype)


# ----------------------------------------------------------------- attention
def init_attention(cfg, gen, dtype, cross=False):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, hk = cfg.n_heads, cfg.n_kv_heads
    p = {
        'wq': mk_param(gen(), (d, h, hd), None, dtype),
        'wk': mk_param(gen(), (d, hk, hd), None, dtype),
        'wv': mk_param(gen(), (d, hk, hd), None, dtype),
        'wo': mk_param(gen(), (h, hd, d), None, dtype, fan_in_dims=(0, 1)),
    }
    axes = {
        'wq': ('embed', 'heads', 'head_dim'),
        'wk': ('embed', 'kv_heads', 'head_dim'),
        'wv': ('embed', 'kv_heads', 'head_dim'),
        'wo': ('heads', 'head_dim', 'embed'),
    }
    if cfg.qkv_bias and not cross:
        for n, sh, ax in (('bq', (h, hd), ('heads', 'head_dim')),
                          ('bk', (hk, hd), ('kv_heads', 'head_dim')),
                          ('bv', (hk, hd), ('kv_heads', 'head_dim'))):
            p[n] = jnp.zeros(sh, dtype)
            axes[n] = ax
    if cfg.qk_norm and not cross:
        p['q_norm'] = jnp.ones((hd,), dtype)
        p['k_norm'] = jnp.ones((hd,), dtype)
        axes['q_norm'] = ('head_dim',)
        axes['k_norm'] = ('head_dim',)
    return p, axes


def _project_qkv(cfg, p, x, kv_src=None, positions=None, rope=True):
    kv_src = x if kv_src is None else kv_src
    q = jnp.einsum('bsd,dhk->bshk', x, p['wq'])
    k = jnp.einsum('bsd,dhk->bshk', kv_src, p['wk'])
    v = jnp.einsum('bsd,dhk->bshk', kv_src, p['wv'])
    if 'bq' in p:
        q, k, v = q + p['bq'], k + p['bk'], v + p['bv']
    if 'q_norm' in p:
        q = rms_norm(q, p['q_norm'])
        k = rms_norm(k, p['k_norm'])
    if rope and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    # Attention activation sharding (see sharding.rules_for_arch): either
    # kv_heads carries the TP axis (divisible case) or seq_q/kv_seq do
    # (context parallelism).  head_dim_act is never sharded — sharding the
    # score-contraction dim would all-reduce every score block.
    q = logical(q, 'batch', 'seq_q', 'heads', 'head_dim_act')
    # K/V stay replicated over the TP axis on the seq dim (every q shard
    # needs every key); 'kv_heads' still claims TP when divisible.
    k = logical(k, 'batch', None, 'kv_heads', 'head_dim_act')
    v = logical(v, 'batch', None, 'kv_heads', 'head_dim_act')
    return q, k, v


def attention_block(cfg, p, x, *, positions, causal=True, window=None,
                    kv_src=None, cross=False):
    """Self or cross attention over full sequences (train/prefill)."""
    q, k, v = _project_qkv(cfg, p, x, kv_src=kv_src, positions=positions,
                           rope=not cross)
    qh = q.transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    o = chunked_attention(qh, kh, vh, causal=causal and not cross,
                          window=window, chunk=cfg.attn_chunk)
    o = o.transpose(0, 2, 1, 3)
    # seq_q claims TP first under context parallelism; heads only when the
    # policy is off — keeps o's sharding identical to the scan's accumulator
    # (mismatched constraints here caused per-chunk resharding copies).
    o = logical(o, 'batch', 'seq_q', 'heads', 'head_dim_act')
    out = jnp.einsum('bshk,hkd->bsd', o, p['wo'])
    return logical(out, 'batch', 'seq', 'embed')


def attention_decode(cfg, p, x, cache, *, pos, window=None, cross_kv=None):
    """One-token decode against a (ring or linear) KV cache.

    cache: {'k','v': (B, W, Hkv, hd), 'pos': (W,) int32 slot->abs position}.
    Softmax is permutation-invariant over keys, so ring order needs no unrolling.
    """
    if cross_kv is not None:
        k_all, v_all = cross_kv                      # (B, F, Hk, hd) static
        q, _, _ = _project_qkv(cfg, p, x, kv_src=x, positions=None, rope=False)
        # cross-attn k/v are precomputed from the encoder/image source
        qh = q.transpose(0, 2, 1, 3)
        o = chunked_attention(qh, k_all.transpose(0, 2, 1, 3),
                              v_all.transpose(0, 2, 1, 3), causal=False,
                              chunk=cfg.attn_chunk)
        o = o.transpose(0, 2, 1, 3)
        return jnp.einsum('bshk,hkd->bsd', o, p['wo']), cache

    w = cache['k'].shape[1]
    positions = jnp.full((1,), pos)
    q, k_new, v_new = _project_qkv(cfg, p, x, positions=positions)
    slot = pos % w
    k_cache = jax.lax.dynamic_update_slice(cache['k'], k_new.astype(cache['k'].dtype),
                                           (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache['v'], v_new.astype(cache['v'].dtype),
                                           (0, slot, 0, 0))
    pos_arr = cache['pos'].at[slot].set(pos)
    cache = {'k': k_cache, 'v': v_cache, 'pos': pos_arr}

    qh = q.transpose(0, 2, 1, 3)                       # (B, H, 1, hd)
    kh = k_cache.transpose(0, 2, 1, 3)                 # (B, Hk, W, hd)
    vh = v_cache.transpose(0, 2, 1, 3)
    b, h, _, hd = qh.shape
    hk = kh.shape[1]
    scale = cfg.resolved_head_dim ** -0.5
    # Validity mask from absolute slot positions (handles ring wrap + window).
    valid = (pos_arr >= 0) & (pos_arr <= pos)
    if window is not None:
        valid &= (pos - pos_arr) < window
    s = jnp.einsum('bhgd,bhkd->bhgk', qh.reshape(b, hk, h // hk, hd),
                   kh, preferred_element_type=f32) * scale
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    pmax = jnp.max(s, axis=-1, keepdims=True)
    pr = jnp.exp(s - pmax)
    pr = pr / jnp.sum(pr, axis=-1, keepdims=True)
    o = jnp.einsum('bhgk,bhkd->bhgd', pr.astype(vh.dtype), vh)
    o = o.reshape(b, h, 1, hd).transpose(0, 2, 1, 3)
    return jnp.einsum('bshk,hkd->bsd', o, p['wo']), cache


# ----------------------------------------------------------------------- MLP
def init_mlp(cfg, gen, dtype, d_ff=None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    if cfg.act == 'silu':   # SwiGLU
        p = {'w_gate': mk_param(gen(), (d, ff), None, dtype),
             'w_up': mk_param(gen(), (d, ff), None, dtype),
             'w_down': mk_param(gen(), (ff, d), None, dtype)}
        axes = {'w_gate': ('embed', 'mlp'), 'w_up': ('embed', 'mlp'),
                'w_down': ('mlp', 'embed')}
    else:
        p = {'w_up': mk_param(gen(), (d, ff), None, dtype),
             'b_up': jnp.zeros((ff,), dtype),
             'w_down': mk_param(gen(), (ff, d), None, dtype),
             'b_down': jnp.zeros((d,), dtype)}
        axes = {'w_up': ('embed', 'mlp'), 'b_up': ('mlp',),
                'w_down': ('mlp', 'embed'), 'b_down': ('embed',)}
    return p, axes


def mlp_block(cfg, p, x):
    if cfg.act == 'silu':
        h = jax.nn.silu(x @ p['w_gate']) * (x @ p['w_up'])
        h = logical(h, 'batch', 'seq', 'mlp')
        out = h @ p['w_down']
    else:
        h = jax.nn.gelu(x @ p['w_up'] + p['b_up'])
        h = logical(h, 'batch', 'seq', 'mlp')
        out = h @ p['w_down'] + p['b_down']
    return logical(out, 'batch', 'seq', 'embed')


# ----------------------------------------------------------------------- MoE
def init_moe(cfg, gen, dtype):
    d, m = cfg.d_model, cfg.moe
    p = {
        'router': mk_param(gen(), (d, m.n_experts), None, f32),
        'w_gate': mk_param(gen(), (m.n_experts, d, m.d_ff), None, dtype,
                           fan_in_dims=(1,)),
        'w_up': mk_param(gen(), (m.n_experts, d, m.d_ff), None, dtype,
                         fan_in_dims=(1,)),
        'w_down': mk_param(gen(), (m.n_experts, m.d_ff, d), None, dtype,
                           fan_in_dims=(1,)),
    }
    axes = {
        'router': ('embed', None),
        'w_gate': ('experts', 'embed', 'expert_mlp'),
        'w_up': ('experts', 'embed', 'expert_mlp'),
        'w_down': ('experts', 'expert_mlp', 'embed'),
    }
    return p, axes


def _moe_group_count(n: int, target_group: int = 4096) -> int:
    """Token groups: at least one per DP shard (dispatch stays shard-local)."""
    from ..sharding import current_rules
    r = current_rules()
    dp = 1
    if r is not None and r.mesh is not None:
        sizes = dict(zip(r.mesh.axis_names, r.mesh.devices.shape))
        dp = sizes.get('pod', 1) * sizes.get('data', 1)
    g = min(n, max(dp, n // target_group))
    while n % g:
        g -= 1
    return max(g, 1)


def moe_block(cfg, p, x):
    """Top-k MoE with *hierarchical* dispatch: per-group local sort/scatter,
    one expert-axis all-to-all (via resharding constraint), grouped GEMM.

    Chipmunk C3 at pod scale: the expert weights (2 TB for kimi-k2) stay
    stationary, sharded EP x TP; only activation slots move.  The earlier
    global-argsort dispatch (kept as moe_block_global for ablation) made
    SPMD lower every cross-shard gather to a full all-reduce — measured
    117 TB/chip/step on kimi-k2 train.  Here every sort/scatter/gather is
    *within* a token group that lives on one DP shard, so the only
    communication is the intrinsic buf exchange: ~n*k*cf*d bytes/layer.
    """
    m = cfg.moe
    b, s, d = x.shape
    n = b * s
    k, e = m.top_k, m.n_experts
    g = _moe_group_count(n)
    sg = n // g                                     # tokens per group
    pairs = sg * k
    cap = max(int(np.ceil(pairs / e * m.capacity_factor / 8)) * 8, 8)

    xg = logical(x.reshape(g, sg, d), 'batch', None, None)
    router_logits = xg.astype(f32) @ p['router']               # (G, Sg, E)
    gate_vals, idx = jax.lax.top_k(router_logits, k)           # (G, Sg, k)
    gate_vals = jax.nn.softmax(gate_vals, axis=-1)

    pair_expert = idx.reshape(g, pairs)                        # (G, P)
    order = jnp.argsort(pair_expert, axis=-1)                  # local sort
    sorted_expert = jnp.take_along_axis(pair_expert, order, axis=-1)
    # position within expert: rank - first occurrence (vmapped searchsorted)
    starts = jax.vmap(lambda row: jnp.searchsorted(
        row, jnp.arange(e), side='left'))(sorted_expert)       # (G, E)
    pos = jnp.arange(pairs)[None, :] - jnp.take_along_axis(
        starts, sorted_expert, axis=-1)
    keep = pos < cap
    slot = jnp.where(keep, sorted_expert * cap + pos, e * cap)  # (G, P)

    token_of_pair = order // k                                 # (G, P)
    xk = jnp.take_along_axis(
        xg, token_of_pair[..., None], axis=1)                  # (G, P, d) local
    # Dispatch as a *batched gather* (slot -> source pair), not a scatter:
    # SPMD replicates batched scatters ("involuntary full rematerialization",
    # measured 53 TB/chip on kimi-k2), but partitions batched gathers cleanly.
    counts = jnp.concatenate([starts[:, 1:], jnp.full((g, 1), pairs)],
                             axis=1) - starts                  # (G, E)
    cgrid = jnp.arange(cap)[None, None, :]                     # (1, 1, C)
    src_pair = jnp.where(cgrid < counts[..., None],
                         starts[..., None] + cgrid, pairs)     # (G, E, C)
    xk_pad = jnp.concatenate([xk, jnp.zeros((g, 1, d), x.dtype)], axis=1)
    buf = jnp.take_along_axis(
        xk_pad, src_pair.reshape(g, e * cap)[..., None], axis=1)
    buf = buf.reshape(g, e, cap, d)

    # ---- the all-to-all: token-sharded -> expert+cap-sharded (EP x TP) ----
    # cap (not expert_mlp) carries the TP axis: each (expert, cap-slice) GEMM
    # contracts over unsharded d/f, so no down-projection all-reduce exists,
    # and the dispatch all-to-all moves 1/TP of the buffer per chip.
    buf_e = logical(jnp.swapaxes(buf, 0, 1), 'experts', None, 'moe_cap', 'embed')
    h = jax.nn.silu(jnp.einsum('egcd,edf->egcf', buf_e, p['w_gate'])) \
        * jnp.einsum('egcd,edf->egcf', buf_e, p['w_up'])
    h = logical(h, 'experts', None, 'moe_cap', None)
    y_e = jnp.einsum('egcf,efd->egcd', h, p['w_down'])
    y_e = logical(y_e, 'experts', None, 'moe_cap', 'embed')
    # ---- reverse all-to-all: expert-sharded -> token-sharded ----
    y_buf = logical(jnp.swapaxes(y_e, 0, 1), 'batch', None, None, None)
    y_buf = y_buf.reshape(g, e * cap, d)

    y_sorted = jnp.take_along_axis(
        y_buf, jnp.minimum(slot, e * cap - 1)[..., None], axis=1)
    y_sorted = y_sorted * keep[..., None].astype(x.dtype)
    inv = jnp.argsort(order, axis=-1)
    y_pairs = jnp.take_along_axis(y_sorted, inv[..., None], axis=1)
    out = jnp.sum(y_pairs.reshape(g, sg, k, d)
                  * gate_vals[..., None].astype(x.dtype), axis=2)

    # GShard load-balance aux loss from per-group expert loads.
    probs_mean = jnp.mean(jax.nn.softmax(router_logits, -1), axis=(0, 1))
    load = jnp.mean((jax.nn.one_hot(idx, e, dtype=f32)).sum(2), axis=(0, 1)) / k
    ce = jnp.sum(probs_mean * load) * e
    return logical(out.reshape(b, s, d), 'batch', 'seq', 'embed'), ce


def moe_block_global(cfg, p, x):
    """Reference dispatch with one global argsort (ablation baseline; see
    moe_block docstring for why this is catastrophic under SPMD)."""
    m = cfg.moe
    b, s, d = x.shape
    n = b * s
    k, e = m.top_k, m.n_experts
    xf = x.reshape(n, d)
    xf = logical(xf, 'batch', None)

    router_logits = (xf.astype(f32) @ p['router'])            # (N, E)
    gate_vals, idx = jax.lax.top_k(router_logits, k)          # (N, k)
    gate_vals = jax.nn.softmax(gate_vals, axis=-1)

    pair_expert = idx.reshape(-1)                             # (N*k,)
    perm = jnp.argsort(pair_expert)
    sorted_expert = pair_expert[perm]
    sorted_token = perm // k                                  # pair -> token id
    counts = jnp.bincount(pair_expert, length=e)
    starts = jnp.cumsum(counts) - counts
    pos_within = jnp.arange(n * k) - starts[sorted_expert]
    cap = int(np.ceil(n * k / e * m.capacity_factor / 8)) * 8
    keep = pos_within < cap
    slot = jnp.where(keep, sorted_expert * cap + pos_within, e * cap)

    xg = jnp.take(xf, sorted_token, axis=0)                   # (N*k, d) gather
    buf = jnp.zeros((e * cap, d), x.dtype).at[slot].set(xg, mode='drop')
    buf = logical(buf.reshape(e, cap, d), 'experts', None, 'embed')

    h = jax.nn.silu(jnp.einsum('ecd,edf->ecf', buf, p['w_gate'])) \
        * jnp.einsum('ecd,edf->ecf', buf, p['w_up'])
    h = logical(h, 'experts', None, 'expert_mlp')
    y_e = jnp.einsum('ecf,efd->ecd', h, p['w_down'])
    y_e = logical(y_e, 'experts', None, 'embed')

    y_sorted = jnp.take(y_e.reshape(e * cap, d), jnp.minimum(slot, e * cap - 1),
                        axis=0) * keep[:, None].astype(x.dtype)
    y_pairs = jnp.zeros((n * k, d), x.dtype).at[perm].set(y_sorted)
    out = jnp.sum(y_pairs.reshape(n, k, d)
                  * gate_vals[..., None].astype(x.dtype), axis=1)
    me = jnp.mean(jax.nn.softmax(router_logits, -1), axis=0)
    ce = jnp.mean((jnp.bincount(pair_expert, length=e) / (n * k)).astype(f32) * me) * e * e
    return logical(out.reshape(b, s, d), 'batch', 'seq', 'embed'), ce


# ----------------------------------------------------------------- embedding
def init_embedding(cfg, gen, dtype):
    p = {'table': mk_param(gen(), (cfg.vocab_size, cfg.d_model), None, dtype,
                           scale=0.02)}
    axes = {'table': ('vocab', 'embed')}
    if not cfg.tie_embeddings:
        p['unembed'] = mk_param(gen(), (cfg.d_model, cfg.vocab_size), None, dtype)
        axes['unembed'] = ('embed', 'vocab')
    return p, axes


def embed(cfg, p, tokens):
    x = jnp.take(p['table'], tokens, axis=0)
    return logical(x.astype(cfg.adtype()), 'batch', 'seq', 'embed')


def unembed(cfg, p, x):
    w = p['table'].T if cfg.tie_embeddings else p['unembed']
    logits = jnp.einsum('bsd,dv->bsv', x, w.astype(x.dtype))
    return logical(logits, 'batch', 'seq', 'vocab')


def softmax_xent(logits, labels):
    """Token-mean cross entropy, fp32 logsumexp over (sharded) vocab."""
    logits = logits.astype(f32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)
