"""Recurrent / hybrid architectures: xLSTM (mLSTM + sLSTM), Mamba, Hymba.

These are the families where the paper's technique applies directly (DESIGN.md §4):
the sLSTM block is an LSTM-descendant recurrence (Chipmunk's exact workload — its
recurrent mat-vec follows the same weight-stationary systolic schedule), and the
Mamba/mLSTM state updates are weight-stationary scans.

mLSTM uses the *chunkwise-parallel* form (matrix memory C (dh x dh) materialised
only at chunk boundaries) — the recurrent form would store T x dh^2 residuals.
Mamba uses an associative scan (diagonal SSM).  sLSTM is a true nonlinear
recurrence and scans sequentially, exactly like the silicon's column loop.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ArchConfig
from ..sharding import logical
from . import layers as L

f32 = jnp.float32


# =============================================================== mLSTM block
def init_mlstm(cfg: ArchConfig, key):
    gen = L.keygen(key)
    d = cfg.d_model
    di = 2 * d                      # projection factor 2 (xLSTM paper)
    h = cfg.n_heads
    dtype = cfg.dtype()
    p = {
        'ln': jnp.ones((d,), dtype),
        'w_up': L.mk_param(gen(), (d, 2 * di), None, dtype),
        'conv_w': L.mk_param(gen(), (cfg.conv_kernel, di), None, dtype,
                             scale=cfg.conv_kernel ** -0.5),
        'wq': L.mk_param(gen(), (di, di), None, dtype),
        'wk': L.mk_param(gen(), (di, di), None, dtype),
        'wv': L.mk_param(gen(), (di, di), None, dtype),
        'w_if': L.mk_param(gen(), (di, 2 * h), None, dtype, scale=0.01),
        'b_if': jnp.concatenate([jnp.zeros((h,), dtype),
                                 jnp.linspace(3.0, 6.0, h).astype(dtype)]),
        'out_norm': jnp.ones((di,), dtype),
        'w_down': L.mk_param(gen(), (di, d), None, dtype),
    }
    # Megatron-style block sharding: gather the post-conv stream ONCE, run
    # q/k/v with *output*-sharded weights (local GEMMs), reduce once at
    # w_down.  Input-sharded q/k/v weights would all-reduce (B,S,di) three
    # times per block (measured on the xlstm-1.3b train hillclimb).
    axes = {
        'ln': ('embed',), 'w_up': ('embed', 'mlp'), 'conv_w': (None, None),
        'wq': (None, 'mlp'), 'wk': (None, 'mlp'), 'wv': (None, 'mlp'),
        'w_if': (None, None), 'b_if': (None,),
        'out_norm': ('mlp',), 'w_down': ('mlp', 'embed'),
    }
    return p, axes


def _causal_conv(x, w, state=None):
    """Depthwise causal conv.  x: (B,S,C); w: (K,C).  state: (B,K-1,C) or None."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else None
    return out, new_state


def mlstm_chunkwise(q, k, v, log_f, log_i, chunk: int, state=None):
    """Stabilised chunkwise mLSTM.  q,k,v: (B,H,S,dh); log_f, log_i: (B,H,S).

    Returns (y (B,H,S,dh), final state (C, n, m)).  Within-chunk: attention-like
    masked decay matrix; across chunks: matrix memory recurrence at boundaries.
    """
    b, h, s, dh = q.shape
    nc = s // chunk
    q = q.reshape(b, h, nc, chunk, dh)   # k is pre-scaled by dh^-0.5 upstream
    k = k.reshape(b, h, nc, chunk, dh)
    v = v.reshape(b, h, nc, chunk, dh)
    lf = log_f.astype(f32).reshape(b, h, nc, chunk)
    li = log_i.astype(f32).reshape(b, h, nc, chunk)

    csum_f = jnp.cumsum(lf, axis=-1)                    # within-chunk cumulative
    total_f = csum_f[..., -1]                           # (B,H,nc)
    # decay from step t to end of chunk / from chunk start to step t
    dec_to_end = total_f[..., None] - csum_f            # (B,H,nc,T)
    # source weight for boundary state update: i_t * f_{t+1..T}
    src = li + dec_to_end

    if state is None:
        c0 = jnp.zeros((b, h, dh, dh), f32)
        n0 = jnp.zeros((b, h, dh), f32)
        m0 = jnp.full((b, h), -1e30, f32)
    else:
        c0, n0, m0 = state

    def outer(carry, xs):
        c_prev, n_prev, m_prev = carry
        qc, kc, vc, lf_c, li_c, csum_c, tot_c, src_c = xs
        # (B,H,T) intra-chunk log weights: D_ts = csum_t - csum_s + li_s (s<=t)
        dmat = csum_c[..., :, None] - csum_c[..., None, :] + li_c[..., None, :]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        dmat = jnp.where(tri, dmat, -1e30)
        # stabiliser per row: max(intra max, inter bound m_prev + csum_t)
        inter_bound = m_prev[..., None] + csum_c                  # (B,H,T)
        m_row = jnp.maximum(jnp.max(dmat, axis=-1), inter_bound)
        m_row = jnp.maximum(m_row, -1e29)
        dmat = jnp.exp(dmat - m_row[..., None])
        inter_w = jnp.exp(inter_bound - m_row)                    # (B,H,T)

        s_qk = jnp.einsum('bhtd,bhsd->bhts', qc.astype(f32), kc.astype(f32),
                          preferred_element_type=f32)
        y_intra = jnp.einsum('bhts,bhsd->bhtd', s_qk * dmat, vc.astype(f32))
        y_inter = jnp.einsum('bhtd,bhde->bhte', qc.astype(f32), c_prev) \
            * inter_w[..., None]
        n_intra = jnp.einsum('bhts,bhs->bht', s_qk * dmat,
                             jnp.ones((b, h, chunk), f32))
        # normaliser: |q . n| terms
        n_inter = jnp.einsum('bhtd,bhd->bht', qc.astype(f32), n_prev) \
            * inter_w
        denom = jnp.maximum(jnp.abs(n_intra + n_inter), jnp.exp(-m_row))
        y = (y_intra + y_inter) / denom[..., None]

        # boundary update (new running max at chunk end)
        m_new = jnp.maximum(m_prev + tot_c, jnp.max(li_c + (tot_c[..., None]
                            - csum_c), axis=-1))
        w_src = jnp.exp(src_c - m_new[..., None])                 # (B,H,T)
        c_new = c_prev * jnp.exp(m_prev + tot_c - m_new)[..., None, None] \
            + jnp.einsum('bhtd,bhte,bht->bhde', kc.astype(f32),
                         vc.astype(f32), w_src)
        n_new = n_prev * jnp.exp(m_prev + tot_c - m_new)[..., None] \
            + jnp.einsum('bhtd,bht->bhd', kc.astype(f32), w_src)
        return (c_new, n_new, m_new), y

    xs = tuple(jnp.moveaxis(a, 2, 0) for a in
               (q, k, v, lf, li, csum_f, total_f, src))
    (c_f, n_f, m_f), ys = jax.lax.scan(outer, (c0, n0, m0), xs)
    y = jnp.moveaxis(ys, 0, 2).reshape(b, h, s, dh)
    return y.astype(v.dtype), (c_f, n_f, m_f)


def mlstm_block(cfg: ArchConfig, p, x, *, chunk=256, state=None):
    """x: (B,S,D) -> (B,S,D), optionally carrying (conv_state, (C,n,m))."""
    b, s, d = x.shape
    h = cfg.n_heads
    di = 2 * d
    dh = di // h
    res = x
    xn = L.rms_norm(x, p['ln'])
    up = xn @ p['w_up']
    m_in, z = jnp.split(up, 2, axis=-1)
    conv_state = state[0] if state is not None else None
    cx, conv_state = _causal_conv(m_in, p['conv_w'], conv_state)
    cx = jax.nn.silu(cx)
    # conv/silu run TP-sharded (elementwise in di); gather only the matmul
    # inputs (full-matrix q/k/v projections need the whole di stream)
    cx = L.logical(cx, 'batch', 'seq', None)
    m_in = L.logical(m_in, 'batch', 'seq', None)
    q = (cx @ p['wq']).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    k = (cx @ p['wk']).reshape(b, s, h, dh).transpose(0, 2, 1, 3) * dh ** -0.5
    v = (m_in @ p['wv']).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    gates = m_in @ p['w_if'] + p['b_if']          # (B,S,2H)
    log_i = gates[..., :h].astype(f32).transpose(0, 2, 1)
    log_f = jax.nn.log_sigmoid(gates[..., h:].astype(f32)).transpose(0, 2, 1)

    chunk_eff = min(chunk, s)
    if s % chunk_eff:
        chunk_eff = s                               # smoke shapes
    mem_state = state[1] if state is not None else None
    y, mem_state = mlstm_chunkwise(q, k, v, log_f, log_i, chunk_eff, mem_state)
    y = y.transpose(0, 2, 1, 3).reshape(b, s, di)
    y = L.rms_norm(y, p['out_norm']) * jax.nn.silu(z)
    out = (res + y @ p['w_down']).astype(res.dtype)
    return out, (conv_state, mem_state)


# =============================================================== sLSTM block
def init_slstm(cfg: ArchConfig, key):
    gen = L.keygen(key)
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    dtype = cfg.dtype()
    ffd = int(d * 4 / 3)
    p = {
        'ln': jnp.ones((d,), dtype),
        'w_in': L.mk_param(gen(), (4, d, d), None, dtype, fan_in_dims=(1,)),
        'r': L.mk_param(gen(), (4, h, dh, dh), None, dtype, fan_in_dims=(2,)),
        'b': jnp.zeros((4, d), dtype),
        'out_norm': jnp.ones((d,), dtype),
        'ln_ff': jnp.ones((d,), dtype),
        'w_ff1': L.mk_param(gen(), (d, 2 * ffd), None, dtype),
        'w_ff2': L.mk_param(gen(), (ffd, d), None, dtype),
    }
    axes = {
        'ln': ('embed',), 'w_in': (None, 'embed', 'state'),
        # recurrent weights sharded on the OUTPUT dh (contraction dim must
        # stay local or every timestep all-reduces the gate pre-activations)
        'r': (None, 'heads', None, 'state'), 'b': (None, 'state'),
        'out_norm': ('state',), 'ln_ff': ('embed',),
        'w_ff1': ('embed', 'mlp'), 'w_ff2': ('mlp', 'embed'),
    }
    return p, axes


def slstm_scan(p, zx, state, n_heads, backend: str = 'auto'):
    """Sequential sLSTM recurrence (exp input gate, normaliser + stabiliser).

    zx: (B,S,4,D) pre-computed input contributions (gate order z,i,f,o).
    The recurrent mat-vec r @ h is block-diagonal per head — the exact
    structure Chipmunk's systolic tiles execute (core/systolic.py).

    ``backend`` follows the selector of ``core.lstm`` (DESIGN.md §3.3, §6,
    including ``pallas_seq_systolic``).  The input contribution ``zx`` is
    already hoisted out of the loop (the pallas_seq dataflow); the sLSTM
    elementwise phase (exp gates, normaliser, stabiliser) is not yet ported
    into the sequence kernel or its scale-out, so every backend currently
    resolves to the XLA scan here — the hook exists so call sites are ready
    the day the kernel grows that epilogue.
    """
    from ..core.lstm import BACKENDS
    assert backend in BACKENDS, backend
    b, s, _, d = zx.shape
    h = n_heads
    dh = d // h

    def step(carry, zx_t):
        c, n, m, hid = carry
        hh = hid.reshape(b, h, dh)
        rec = jnp.einsum('ghde,bhd->bghe', p['r'].astype(f32), hh
                         ).reshape(b, 4, d)
        pre = zx_t.astype(f32) + rec
        z = jnp.tanh(pre[:, 0])
        log_i = pre[:, 1]
        log_f = jax.nn.log_sigmoid(pre[:, 2])
        o = jax.nn.sigmoid(pre[:, 3])
        m_new = jnp.maximum(log_f + m, log_i)
        i_s = jnp.exp(log_i - m_new)
        f_s = jnp.exp(log_f + m - m_new)
        c_new = f_s * c + i_s * z
        n_new = jnp.maximum(f_s * n + i_s, 1e-6)
        hid_new = o * (c_new / n_new)
        return (c_new, n_new, m_new, hid_new), hid_new

    if state is None:
        zeros = jnp.zeros((b, d), f32)
        state = (zeros, zeros + 1e-6, zeros - 10.0, zeros)
    state, ys = jax.lax.scan(step, state, jnp.moveaxis(zx, 1, 0))
    return jnp.moveaxis(ys, 0, 1), state     # (B,S,D)


def slstm_block(cfg: ArchConfig, p, x, state=None):
    b, s, d = x.shape
    res = x
    xn = L.rms_norm(x, p['ln'])
    zx = jnp.einsum('bsd,gde->bsge', xn, p['w_in']) + p['b']   # (B,S,4,D)
    y, state = slstm_scan(p, zx, state, cfg.n_heads,
                          backend=cfg.lstm_backend)
    y = L.rms_norm(y.astype(x.dtype), p['out_norm'])
    x = (res + y).astype(res.dtype)
    # post-FFN (proj factor 4/3, gated)
    h2 = L.rms_norm(x, p['ln_ff'])
    u = h2 @ p['w_ff1']
    a, g = jnp.split(u, 2, axis=-1)
    return (x + (jax.nn.silu(g) * a) @ p['w_ff2']).astype(res.dtype), state


# ================================================================ xLSTM model
def init_xlstm(cfg: ArchConfig, key):
    gen = L.keygen(key)
    dtype = cfg.dtype()
    per = cfg.xlstm_slstm_every
    n_groups = cfg.n_layers // per
    params: Dict[str, Any] = {}
    axes: Dict[str, Any] = {}
    params['embed'], axes['embed'] = L.init_embedding(cfg, gen, dtype)

    from .transformer import _stack_init
    params['mlstm'], axes['mlstm'] = _stack_init(
        lambda k: _stack_init(lambda kk: init_mlstm(cfg, kk), k, per - 1),
        gen(), n_groups)
    params['slstm'], axes['slstm'] = _stack_init(
        lambda k: init_slstm(cfg, k), gen(), n_groups)
    params['final_norm'] = {'scale': jnp.ones((cfg.d_model,), dtype)}
    axes['final_norm'] = {'scale': ('embed',)}
    return params, axes


def forward_xlstm(cfg: ArchConfig, params, tokens, states=None):
    """(B,S) -> logits.  states: per-layer recurrent states for decode."""
    x = L.embed(cfg, params['embed'], tokens)
    per = cfg.xlstm_slstm_every

    decode = states is not None

    def body(x, xs):
        if decode:
            (mgroup, sblk), (mstates, sstate) = xs
        else:
            mgroup, sblk = xs
            mstates = sstate = None
        new_m = []
        for i in range(per - 1):
            blk = jax.tree.map(lambda a: a[i], mgroup)
            st = jax.tree.map(lambda a: a[i], mstates) if decode else None
            x, st = mlstm_block(cfg, blk, x, state=st)
            new_m.append(st)
        x, sstate = slstm_block(cfg, sblk, x, state=sstate)
        stacked_m = jax.tree.map(lambda *a: jnp.stack(a), *new_m)
        return x, (stacked_m, sstate)

    def scan_body(x, xs):
        x, sts = body(x, xs)
        return x, sts

    fn = scan_body
    if cfg.remat != 'none' and not decode:
        fn = jax.checkpoint(scan_body)
    xs = (params['mlstm'], params['slstm'])
    if decode:
        xs = (xs, states)
    x, new_states = jax.lax.scan(fn, x, xs)
    x = L.rms_norm(x, params['final_norm']['scale'])
    return L.unembed(cfg, params['embed'], x), new_states


def init_xlstm_state(cfg: ArchConfig, batch: int):
    """Recurrent state stand-in for decode (replaces the KV cache)."""
    per = cfg.xlstm_slstm_every
    n_groups = cfg.n_layers // per
    d = cfg.d_model
    di, h = 2 * d, cfg.n_heads
    dh = di // h
    dt = cfg.adtype()
    conv = jnp.zeros((n_groups, per - 1, batch, cfg.conv_kernel - 1, di), dt)
    mem = (jnp.zeros((n_groups, per - 1, batch, h, dh, dh), f32),
           jnp.zeros((n_groups, per - 1, batch, h, dh), f32),
           jnp.full((n_groups, per - 1, batch, h), -1e30, f32))
    zeros = jnp.zeros((n_groups, batch, d), f32)
    sstate = (zeros, zeros + 1e-6, zeros - 10.0, zeros)
    states = ((conv, mem), sstate)
    conv_ax = ('layers', None, 'batch', None, 'mlp')
    mem_ax = (('layers', None, 'batch', 'heads', 'head_dim', None),
              ('layers', None, 'batch', 'heads', 'head_dim'),
              ('layers', None, 'batch', 'heads'))
    s_ax = ('layers', 'batch', 'embed')
    axes = ((conv_ax, mem_ax), (s_ax, s_ax, s_ax, s_ax))
    return states, axes


# ================================================================ Mamba block
def init_mamba(cfg: ArchConfig, key):
    gen = L.keygen(key)
    d = cfg.d_model
    di = d                          # hymba: mamba heads span d_model
    n = cfg.ssm_state
    dtype = cfg.dtype()
    dt_rank = max(d // 16, 1)
    p = {
        'w_in': L.mk_param(gen(), (d, 2 * di), None, dtype),
        'conv_w': L.mk_param(gen(), (cfg.conv_kernel, di), None, dtype,
                             scale=cfg.conv_kernel ** -0.5),
        'w_bdt': L.mk_param(gen(), (di, 2 * n + dt_rank), None, dtype),
        'w_dt': L.mk_param(gen(), (dt_rank, di), None, dtype),
        'b_dt': jnp.asarray(
            np.log(np.expm1(np.random.RandomState(0).uniform(
                1e-3, 1e-1, size=(di,)))), dtype),
        'a_log': jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=f32), (di, 1))),
        'd_skip': jnp.ones((di,), dtype),
        'w_out': L.mk_param(gen(), (di, d), None, dtype),
    }
    axes = {
        'w_in': ('embed', 'mlp'), 'conv_w': (None, 'mlp'),
        'w_bdt': ('mlp', None), 'w_dt': (None, 'mlp'), 'b_dt': ('mlp',),
        'a_log': ('mlp', None), 'd_skip': ('mlp',), 'w_out': ('mlp', 'embed'),
    }
    return p, axes


def mamba_mix(cfg: ArchConfig, p, x, state=None):
    """Selective SSM (Mamba-1) via associative scan.  x: (B,S,D)."""
    b, s, d = x.shape
    n = cfg.ssm_state
    dt_rank = p['w_dt'].shape[0]
    u, z = jnp.split(x @ p['w_in'], 2, axis=-1)       # (B,S,di) each
    conv_state = state[0] if state is not None else None
    u_c, conv_state = _causal_conv(u, p['conv_w'], conv_state)
    u_c = jax.nn.silu(u_c)

    bdt = u_c @ p['w_bdt']
    b_mat = bdt[..., :n].astype(f32)                  # (B,S,N)
    c_mat = bdt[..., n:2 * n].astype(f32)
    dt = jax.nn.softplus(bdt[..., 2 * n:] @ p['w_dt'] + p['b_dt']).astype(f32)
    a = -jnp.exp(p['a_log'])                          # (di,N)

    a_bar = jnp.exp(dt[..., None] * a)                # (B,S,di,N)
    bx = dt[..., None] * b_mat[..., None, :] * u_c.astype(f32)[..., None]

    ssm_prev = state[1] if state is not None else None
    if ssm_prev is not None:
        # seed the scan with the carried state via a virtual step 0
        bx = bx.at[:, 0].add(a_bar[:, 0] * ssm_prev)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, hs = jax.lax.associative_scan(combine, (a_bar, bx), axis=1)
    y = jnp.einsum('bsdn,bsn->bsd', hs, c_mat).astype(x.dtype)
    y = y + u_c * p['d_skip']
    y = y * jax.nn.silu(z)
    return y @ p['w_out'], (conv_state, hs[:, -1])


# ================================================================ Hymba model
def init_hymba_block(cfg: ArchConfig, key):
    gen = L.keygen(key)
    dtype = cfg.dtype()
    p, ax = {}, {}
    p['ln1'], ax['ln1'] = L.init_norm(cfg, dtype)
    p['attn'], ax['attn'] = L.init_attention(cfg, gen, dtype)
    p['mamba'], ax['mamba'] = init_mamba(cfg, gen())
    p['attn_norm'] = jnp.ones((cfg.d_model,), dtype)
    p['mamba_norm'] = jnp.ones((cfg.d_model,), dtype)
    ax['attn_norm'] = ('embed',)
    ax['mamba_norm'] = ('embed',)
    p['ln2'], ax['ln2'] = L.init_norm(cfg, dtype)
    p['mlp'], ax['mlp'] = L.init_mlp(cfg, gen, dtype)
    return p, ax


def init_hymba(cfg: ArchConfig, key):
    from .transformer import _stack_init
    gen = L.keygen(key)
    dtype = cfg.dtype()
    params, axes = {}, {}
    params['embed'], axes['embed'] = L.init_embedding(cfg, gen, dtype)
    params['blocks'], axes['blocks'] = _stack_init(
        lambda k: init_hymba_block(cfg, k), gen(), cfg.n_layers)
    params['meta'] = L.mk_param(gen(), (128, cfg.d_model), None, dtype, scale=0.02)
    axes['meta'] = (None, 'embed')
    params['final_norm'], axes['final_norm'] = L.init_norm(cfg, dtype)
    return params, axes


def hymba_block(cfg, blk, x, positions, is_global, state=None, pos=None,
                cache=None):
    """Parallel attention + mamba heads, fused by normalised averaging."""
    res_dt = x.dtype
    h = L.apply_norm(cfg, blk['ln1'], x)
    if cache is None:
        # Traced window: global layers see everything (inf), others SWA —
        # one attention program serves both inside the layer scan.
        window = jnp.where(is_global, jnp.inf,
                           jnp.float32(cfg.sliding_window))
        attn_out = L.attention_block(cfg, blk['attn'], h, positions=positions,
                                     causal=True, window=window)
        new_cache = None
    else:
        window = None if is_global else cfg.sliding_window
        attn_out, new_cache = L.attention_decode(cfg, blk['attn'], h, cache,
                                                 pos=pos, window=window)
    mamba_out, state = mamba_mix(cfg, blk['mamba'], h, state=state)
    fused = 0.5 * (L.rms_norm(attn_out, blk['attn_norm'])
                   + L.rms_norm(mamba_out, blk['mamba_norm']))
    x = (x + fused).astype(res_dt)
    h2 = L.apply_norm(cfg, blk['ln2'], x)
    return (x + L.mlp_block(cfg, blk['mlp'], h2)).astype(res_dt), state, new_cache


def forward_hymba(cfg: ArchConfig, params, tokens):
    x = L.embed(cfg, params['embed'], tokens)
    b = x.shape[0]
    meta = jnp.broadcast_to(params['meta'][None].astype(x.dtype),
                            (b,) + params['meta'].shape)
    x = jnp.concatenate([meta, x], axis=1)           # prepend meta tokens
    positions = jnp.arange(x.shape[1])
    n_meta = params['meta'].shape[0]
    glob = jnp.zeros((cfg.n_layers,), bool)
    for i in cfg.global_layer_ids:
        glob = glob.at[i].set(True)

    def body(x, xs):
        blk, is_g = xs
        x, _, _ = hymba_block(cfg, blk, x, positions, is_g)
        return x, None

    fn = jax.checkpoint(body) if cfg.remat != 'none' else body
    x, _ = jax.lax.scan(fn, x, (params['blocks'], glob))
    x = L.apply_norm(cfg, params['final_norm'], x)[:, n_meta:]
    return L.unembed(cfg, params['embed'], x)


def init_hymba_cache(cfg: ArchConfig, batch: int, max_seq: int):
    """Per-layer cache list: global layers hold the full span + meta tokens,
    SWA layers a ring buffer of the window — the point of the hybrid design
    (O(window) memory for 29 of 32 layers even at 524k context)."""
    hk, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    dt = cfg.adtype()
    n_meta = 128
    span = max_seq + n_meta
    di = cfg.d_model
    caches, axes = [], []
    kv_ax = {'k': ('batch', 'kv_seq', 'kv_heads', 'head_dim_act'),
             'v': ('batch', 'kv_seq', 'kv_heads', 'head_dim_act'),
             'pos': ('kv_seq',),
             'conv': ('batch', None, 'mlp'),
             'ssm': ('batch', 'mlp', None)}
    for layer in range(cfg.n_layers):
        w = span if layer in cfg.global_layer_ids \
            else min(cfg.sliding_window or span, span)
        caches.append({
            'k': jnp.zeros((batch, w, hk, hd), dt),
            'v': jnp.zeros((batch, w, hk, hd), dt),
            'pos': jnp.full((w,), -1, jnp.int32),
            'conv': jnp.zeros((batch, cfg.conv_kernel - 1, di), dt),
            'ssm': jnp.zeros((batch, di, cfg.ssm_state), f32),
        })
        axes.append(dict(kv_ax))
    return caches, axes


def hymba_decode_step(cfg: ArchConfig, params, cache, tokens, pos):
    """One-token decode; unrolled over the 32 layers (heterogeneous caches)."""
    x = L.embed(cfg, params['embed'], tokens)
    pos_eff = pos + 128               # meta tokens occupy the cache prefix

    new_cache = []
    for layer in range(cfg.n_layers):
        blk = jax.tree.map(lambda a: a[layer], params['blocks'])
        c = cache[layer]
        is_g = layer in cfg.global_layer_ids
        h = L.apply_norm(cfg, blk['ln1'], x)
        kv = {k: c[k] for k in ('k', 'v', 'pos')}
        attn_out, kv = L.attention_decode(
            cfg, blk['attn'], h, kv, pos=pos_eff,
            window=None if is_g else cfg.sliding_window)
        mamba_out, (cv, sm) = mamba_mix(cfg, blk['mamba'], h,
                                        state=(c['conv'], c['ssm']))
        fused = 0.5 * (L.rms_norm(attn_out, blk['attn_norm'])
                       + L.rms_norm(mamba_out, blk['mamba_norm']))
        x = (x + fused).astype(cfg.adtype())
        h2 = L.apply_norm(cfg, blk['ln2'], x)
        x = (x + L.mlp_block(cfg, blk['mlp'], h2)).astype(cfg.adtype())
        new_cache.append({**kv, 'conv': cv, 'ssm': sm})

    x = L.apply_norm(cfg, params['final_norm'], x)
    return L.unembed(cfg, params['embed'], x), new_cache
