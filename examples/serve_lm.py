"""Batched LM serving with continuous batching (weight-stationary deployment).

Thin front-end over repro.launch.serve's SlotServer — submits a mixed batch of
requests with different prompt/output lengths and reports throughput + latency
percentiles.

    PYTHONPATH=src python examples/serve_lm.py --arch mixtral-8x22b --requests 8
"""
import argparse

from repro.launch.serve import main as serve_main

if __name__ == '__main__':
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', default='qwen3-14b')
    ap.add_argument('--requests', type=int, default=8)
    ap.add_argument('--slots', type=int, default=4)
    ap.add_argument('--max-new', type=int, default=8)
    a = ap.parse_args()
    serve_main(['--arch', a.arch, '--requests', str(a.requests),
                '--slots', str(a.slots), '--max-new', str(a.max_new)])
