"""Quickstart: the Chipmunk systolic LSTM core in 60 lines.

Builds the paper's CTC-3L-421H layer-1 geometry (123 -> 421, 96-unit engines),
runs it three ways — dense oracle, float systolic dataflow, bit-accurate int8
silicon path — and reports the paper's headline numbers from the calibrated
performance model.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import lstm, perf_model as pm, quant, systolic

# --- build the paper's layer-1 geometry ------------------------------------
n_x, n_h = 123, 421                      # MFCC features -> hidden units
params = lstm.init_lstm_params(jax.random.PRNGKey(0), n_x, n_h)
xs = jax.random.normal(jax.random.PRNGKey(1), (20, 4, n_x)) * 0.5  # (T, B, D)

# 1) dense oracle (Eqs. 1-5 of the paper)
hs_ref, _ = lstm.lstm_layer(params, xs)

# 2) the systolic dataflow: 5x7 grid of 96x96 weight-stationary engine tiles
plan = systolic.SystolicPlan(n_x, n_h, tile=systolic.N_LSTM_SILICON)
packed = systolic.pack_lstm(params, plan)
hs_sys = systolic.systolic_layer_tiled(packed, xs)
print(f'systolic grid: {plan.rows} rows x {plan.cols} cols '
      f'({plan.n_engines} engines, {plan.weight_bytes_per_engine():,} B each)')
print(f'float systolic vs dense:  max |err| = '
      f'{float(jnp.max(jnp.abs(hs_sys - hs_ref))):.2e}')

# 3) the silicon datapath: int8 storage, saturating int16 hops, LUT gates
qp = systolic.quantize_packed(packed)
hs_q = systolic.systolic_layer_quantized(qp, quant.quantize(xs, quant.STATE_FMT))
err = jnp.abs(quant.dequantize(hs_q, quant.STATE_FMT) - hs_ref)
print(f'int8 silicon vs dense:    mean |err| = {float(err.mean()):.4f} '
      f'({float(err.mean()) / quant.STATE_FMT.scale:.2f} LSB of Q2.5)')

# --- the headline silicon numbers (calibrated model, Sec. 4.1) -------------
print(f'\npeak performance  @1.24V: {pm.peak_gops(1.24):5.1f} Gop/s '
      f'(paper: 32.3)')
print(f'peak efficiency   @0.75V: {pm.efficiency_gops_per_mw(0.75):5.2f} '
      f'Gop/s/mW (paper: 3.08)')
row = pm.table2_row(pm.CTC_3L_421H, pm.TileConfig(3, 5, 5), 1.24)
print(f'CTC-3L-421H on 3x(5x5)  : {row["exec_time_ms"]:.3f} ms/frame '
      f'(paper: 0.09, deadline {"MET" if row["meets_deadline"] else "MISS"})')
