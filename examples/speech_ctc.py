"""End-to-end paper scenario: train CTC-3L-421H-UNI, quantize, deploy, stream.

The paper's real-world evaluation (Sec. 4.2) runs a 3-layer 421-hidden-unit
LSTM with CTC phoneme outputs on Chipmunk arrays.  This example covers the
whole lifecycle on synthetic MFCC data:

  1. train the full-precision network with CTC loss (the real ~3.8M-weight
     topology — CPU-trainable);
  2. post-training-quantize to the 8-bit systolic format;
  3. compare greedy decodes between fp32 and the bit-accurate int8 path;
  4. stream ragged utterances through the packed serving engine
     (serving.StreamingEngine, DESIGN.md §7) with incremental CTC emission,
     and check the streamed decodes equal the monolithic ones;
  5. report deployment feasibility per Table 2 (10 ms frame deadline).

    PYTHONPATH=src python examples/speech_ctc.py --steps 60
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ShapeConfig, get_config
from repro.core import ctc, perf_model as pm, quant, systolic
from repro.core.lstm import lstm_stack_apply
from repro.data import SyntheticCTC
from repro.models import chipmunk_net, get_bundle
from repro.optim import adamw, apply_updates, clip_by_global_norm, cosine_schedule
from repro.serving import StreamingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--steps', type=int, default=60)
    ap.add_argument('--batch', type=int, default=8)
    ap.add_argument('--frames', type=int, default=64)
    ap.add_argument('--small', action='store_true',
                    help='reduced network for quick runs')
    args = ap.parse_args()

    cfg = get_config('chipmunk-ctc')
    if args.small:
        cfg = cfg.replace(n_layers=2, lstm_hidden=96, d_model=96)
    bundle = get_bundle(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    print(f'CTC-{cfg.n_layers}L-{cfg.lstm_hidden}H: '
          f'{bundle.param_count(params):,} weights '
          f'(paper: ~3.8e6 for the full topology)')

    # ------------------------------------------------------------- training
    shape = ShapeConfig('ctc', 'train', args.frames, args.batch)
    source = SyntheticCTC(cfg, shape, seed=0)
    opt = adamw(cosine_schedule(3e-3, warmup=10, total=args.steps))
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, batch):
        loss, g = jax.value_and_grad(
            lambda p: bundle.loss_fn(p, batch))(params)
        g, gnorm = clip_by_global_norm(g, 1.0)
        updates, opt_state = opt.update(g, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    t0 = time.time()
    for i in range(args.steps):
        host = source.host_batch(i, 0, args.batch)
        batch = {k: jnp.asarray(v) for k, v in host.items()}
        params, opt_state, loss = step(params, opt_state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(f'step {i:4d}  ctc loss {float(loss):8.3f}  '
                  f'({(time.time() - t0) / (i + 1):.2f}s/step)')

    # ------------------------------------------- quantize + systolic deploy
    host = source.host_batch(10_000, 0, args.batch)
    frames = jnp.asarray(host['frames'])
    log_probs_fp = bundle.forward(params, {'frames': frames})

    plans, qps = [], []
    h = jnp.moveaxis(frames, 0, 1)
    x_q = quant.quantize(h, quant.STATE_FMT)
    for i, layer in enumerate(params.layers):
        plan = systolic.SystolicPlan(layer.n_x, layer.n_h,
                                     systolic.N_LSTM_SILICON)
        qp = systolic.quantize_packed(systolic.pack_lstm(layer, plan))
        x_q = systolic.systolic_layer_quantized(qp, x_q)
        plans.append(plan)
        qps.append(qp)
    h_deq = quant.dequantize(x_q, quant.STATE_FMT)
    logits_q = jnp.einsum('oh,tbh->tbo', params.w_out, h_deq) + params.b_out
    log_probs_q = jax.nn.log_softmax(logits_q, axis=-1)

    dec_fp, len_fp = ctc.ctc_greedy_decode(log_probs_fp)
    dec_q, len_q = ctc.ctc_greedy_decode(log_probs_q)
    agree = float(np.mean([
        np.array_equal(np.asarray(dec_fp[b][:int(len_fp[b])]),
                       np.asarray(dec_q[b][:int(len_q[b])]))
        for b in range(args.batch)]))
    print(f'\nint8 systolic deployment: greedy decode agreement '
          f'{agree * 100:.0f}% across {args.batch} utterances')
    print(f'engines per layer: ' + ', '.join(
        f'{p.rows}x{p.cols}' for p in plans))

    # ------------------------------------- streaming serving (DESIGN.md §7)
    # The near-sensor deployment: utterances of ragged lengths arrive as
    # frame streams; the packed engine advances every active stream through
    # one batched chunked call per step and emits phonemes incrementally.
    rng = np.random.RandomState(1)
    host_frames = np.asarray(host['frames'])           # (B, T, n_in)
    n_stream = min(4, args.batch)
    lens = [int(rng.randint(args.frames // 2, args.frames + 1))
            for _ in range(n_stream)]
    utts = [host_frames[b, :L] for b, L in enumerate(lens)]

    engine = StreamingEngine(cfg, params, max_streams=max(2, n_stream // 2),
                             chunk=max(4, args.frames // 8), decode_ctc=True)
    sessions = [engine.submit(u) for u in utts]
    engine.run()

    stream_agree = 0
    for sess, u in zip(sessions, utts):
        mono = bundle.forward(params, {'frames': jnp.asarray(u)[None]})
        dec_mono, len_mono = ctc.ctc_greedy_decode(mono)
        mono_syms = np.asarray(dec_mono[0][:int(len_mono[0])]).tolist()
        stream_agree += int(sess.decoder.symbols == mono_syms)
    stats = engine.stats()
    print(f'\nstreaming engine: {stats["streams"]} ragged utterances '
          f'({stats["frames"]} frames) served in chunks of {engine.chunk}; '
          f'incremental CTC == monolithic decode for '
          f'{stream_agree}/{n_stream} streams '
          f'(p50 chunk {stats["p50_chunk_s"] * 1e3:.1f} ms)')
    first = sessions[0].decoder.symbols
    print(f'  stream 0 incremental phonemes: {first[:12]}'
          + (' ...' if len(first) > 12 else ''))

    # ----------------------------------------------------- Table 2 verdict
    print('\ndeployment feasibility (10 ms MFCC frame deadline):')
    for row in pm.table2():
        flag = 'MET ' if row['meets_deadline'] else 'MISS'
        print(f'  {row["config"]:>16} @{row["voltage"]}V: '
              f'{row["exec_time_ms"]:8.3f} ms  [{flag}]  '
              f'avg {row["avg_power_mw"]:7.2f} mW')


if __name__ == '__main__':
    main()
