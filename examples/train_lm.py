"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Uses the qwen3 block architecture scaled to ~100M params, the full production
substrate (sharded data pipeline, AdamW + cosine, checkpointing, fault-tolerant
runner), on whatever devices are available.

    PYTHONPATH=src python examples/train_lm.py --steps 300        # full run
    PYTHONPATH=src python examples/train_lm.py --steps 20 --tiny  # quick check
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import sharding as shd
from repro.checkpoint import CheckpointManager
from repro.configs import ShapeConfig, get_config
from repro.data import ShardedLoader, source_for
from repro.launch.mesh import resolve_rules
from repro.launch.train import (abstract_train_state, batch_shardings,
                                local_mesh, make_train_step)
from repro.models import get_bundle
from repro.optim import cosine_schedule
from repro.runtime import FaultConfig, FaultTolerantRunner


def lm_100m():
    """qwen3-family block at ~100M params (CPU-trainable)."""
    return get_config('qwen3-14b').replace(
        name='qwen3-100m', n_layers=8, d_model=512, n_heads=8, n_kv_heads=4,
        head_dim=64, d_ff=1536, vocab_size=32768,
        param_dtype='float32', activation_dtype='float32', remat='none')


def lm_tiny():
    return lm_100m().replace(name='qwen3-tiny', n_layers=2, d_model=128,
                             d_ff=384, vocab_size=2048)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--steps', type=int, default=300)
    ap.add_argument('--batch', type=int, default=8)
    ap.add_argument('--seq', type=int, default=256)
    ap.add_argument('--lr', type=float, default=3e-4)
    ap.add_argument('--tiny', action='store_true')
    ap.add_argument('--ckpt-dir', default='/tmp/repro_lm_ckpt')
    ap.add_argument('--resume', action='store_true')
    args = ap.parse_args()

    cfg = lm_tiny() if args.tiny else lm_100m()
    bundle = get_bundle(cfg)
    mesh = local_mesh()
    shape = ShapeConfig('lm', 'train', args.seq, args.batch)
    rules_dict = shd.rules_for_arch(shd.TRAIN_RULES, cfg.n_kv_heads,
                                    mesh.shape.get('model', 1))

    rules = shd.ShardingRules(mesh, resolve_rules(rules_dict, mesh))
    with shd.use_rules(rules):
        _, state_sh, optimizer = abstract_train_state(
            bundle, mesh, rules_dict,
            lr_fn=cosine_schedule(args.lr, warmup=20, total=args.steps))
        step_fn = jax.jit(make_train_step(bundle, optimizer),
                          in_shardings=(state_sh, None), donate_argnums=(0,))

        params, _ = bundle.init(jax.random.PRNGKey(0))
        n = bundle.param_count(params)
        print(f'{cfg.name}: {n / 1e6:.1f}M params on mesh {dict(mesh.shape)}')
        params = jax.device_put(params, state_sh['params'])
        state = {'params': params,
                 'opt': jax.device_put(optimizer.init(params), state_sh['opt']),
                 'step': jnp.zeros((), jnp.int32)}

        ckpt = CheckpointManager(args.ckpt_dir, keep=2)
        start = 0
        if args.resume and ckpt.latest_step() is not None:
            state = ckpt.restore(state, shardings=state_sh)
            start = int(state['step'])
            print(f'resumed from step {start}')

        loader = ShardedLoader(source_for(cfg, shape), shape,
                               batch_shardings(cfg, shape, mesh, rules_dict),
                               start_step=start)
        runner = FaultTolerantRunner(
            step_fn, cfg=FaultConfig(
                heartbeat_path=f'{args.ckpt_dir}/heartbeat.json'))

        t0 = time.time()
        tok_per_step = args.batch * args.seq
        for i, (step, batch) in zip(range(start, args.steps), loader):
            state, metrics = runner.run_step(step, state, batch)
            if step % 20 == 0 or step == args.steps - 1:
                dt = (time.time() - t0) / (i - start + 1)
                print(f'step {step:5d}  loss {float(metrics["loss"]):7.4f}  '
                      f'gnorm {float(metrics["grad_norm"]):6.2f}  '
                      f'{dt:.2f}s/step  {tok_per_step / dt:,.0f} tok/s')
            if (step + 1) % 100 == 0:
                ckpt.save(step + 1, state)
        ckpt.save(args.steps, state, blocking=True)
        loader.close()
        print(f'finished; straggler/fault events: {len(runner.events)}')


if __name__ == '__main__':
    main()
